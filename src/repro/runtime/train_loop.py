"""Training runtime: fused train step + fault-tolerant loop.

``make_train_step`` builds the jitted step (loss -> grads -> clip ->
AdamW), with optional gradient-accumulation microbatching; the sharding
of params/opt-state/batch comes from ``repro.parallel``.

``Trainer`` adds the at-scale runtime behaviours, all testable on CPU:

* **checkpoint/restart** — atomic manifest checkpoints every
  ``ckpt_every`` steps; ``run`` auto-resumes from the latest checkpoint,
  and because the data pipeline is deterministic per (seed, step) a
  killed-and-restarted run reproduces the uninterrupted run exactly
  (asserted in tests).
* **failure injection** — ``failure_at`` raises mid-run to simulate a
  host loss; production behavior (restart from checkpoint, replay) is
  what the test exercises.
* **straggler mitigation** — per-step wall time is tracked against a
  rolling median; steps exceeding ``straggler_factor`` x median are
  recorded and reported.  At pod scale the same detector drives the
  synchronous-with-backup-participants policy: the run log is the
  contract, the collective itself is XLA's.
* **elastic data sharding** — ``SyntheticLMData.shard_for`` keys shards
  by (step, shard, n_shards) so hosts can be re-assigned between steps.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.models import Model
from repro.optim import adamw_init, adamw_update, clip_by_global_norm


def make_train_step(model: Model, tc: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""

    def loss_of(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if tc.microbatches > 1:
            def micro(carry, mb):
                acc, = carry
                (_, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                acc = jax.tree.map(jnp.add, acc, grads)
                return (acc,), metrics
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((tc.microbatches,
                                     x.shape[0] // tc.microbatches)
                                    + x.shape[1:]), batch)
            (grads,), metrics = jax.lax.scan(micro, (zero,), mbs)
            grads = jax.tree.map(lambda g: g / tc.microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        params, opt_state = adamw_update(tc, params, grads, opt_state)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


class StragglerMonitor:
    def __init__(self, factor: float = 3.0, window: int = 32):
        self.factor = factor
        self.times: list = []
        self.window = window
        self.events: list = []

    def observe(self, step: int, dt: float):
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if dt > self.factor * med:
                self.events.append({"step": step, "dt": dt, "median": med})
        self.times.append(dt)

    @property
    def n_events(self):
        return len(self.events)


class Trainer:
    def __init__(self, cfg: ModelConfig, tc: TrainConfig, batch: int,
                 seq: int, ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 50, seed: int = 0,
                 hooks: Optional[Callable] = None):
        self.cfg = cfg
        self.tc = tc
        self.model = Model(cfg)
        self.data = SyntheticLMData(cfg, batch, seq, seed=tc.seed)
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.step_fn = jax.jit(make_train_step(self.model, tc),
                               donate_argnums=(0, 1))
        self.straggler = StragglerMonitor()
        self.hooks = hooks
        self.history: list = []
        key = jax.random.PRNGKey(seed)
        self.params = self.model.init(key)
        self.opt_state = adamw_init(self.params)
        self.step = 0

    # ------------------------------------------------------------------
    def maybe_resume(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        tree = {"params": self.params, "opt": self.opt_state}
        restored, manifest = self.ckpt.restore(tree, step=latest)
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.step = latest
        return True

    def run(self, n_steps: int, failure_at: Optional[int] = None):
        """Run up to global step ``n_steps``; raises at ``failure_at``
        to simulate a node failure (the caller restarts + resumes)."""
        while self.step < n_steps:
            if failure_at is not None and self.step == failure_at:
                raise RuntimeError(f"injected node failure at step "
                                   f"{self.step}")
            batch = {k: jnp.asarray(v)
                     for k, v in self.data.batch_at(self.step).items()}
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.observe(self.step, dt)
            self.step += 1
            self.history.append({"step": self.step, "loss": loss,
                                 "dt": dt})
            if self.hooks:
                self.hooks(self)
            if (self.ckpt is not None and self.step % self.ckpt_every == 0):
                self.save()
        return self.history

    def save(self):
        if self.ckpt is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        self.ckpt.save(self.step, tree)
