from repro.runtime.kvs import DeviceKVS                     # noqa: F401
from repro.runtime.train_loop import Trainer, make_train_step  # noqa: F401
from repro.runtime.serving import ServingEngine             # noqa: F401
