"""Continuous-batching LM decode as a first-class fabric tenant.

``ServingEngine`` made LM decode *reachable* through the fabric — the
host still chose the tokens.  This module closes the loop: the whole
request lifecycle is device-resident, driven by the PR-7 open-loop
generator.  One fused step is

    inject -> client NIC fetch -> server NIC pipeline -> admit ->
    decode pool -> stream tokens -> free slots -> client delivery

with NOTHING host-side in the critical path — the Dagger thesis
(tightly-coupled NIC, single-write RPC issue, §4.4 offload) applied to
the flagship cloud-microservice workload, an LM decode tier.

Request wire format (client -> server, payload words):
  [0] req_id  (== rpc_id)     [1] prompt seed (counter-PRNG key)
  [2] prompt length           [3] max new tokens
Prompts are never shipped: token ``j`` is the pure hash
``prompt_token(seed, j, vocab)``, so a 1-slot RPC names an arbitrarily
long prompt and every engine (batched, sharded, oracle) derives the
SAME tokens — the request is a seed, exactly like the load itself.

Token streaming (server -> client): each generated token leaves as one
FRAGMENT of the request's logical (>MTU) response — payload
``[req_id, token, emitted, tstamp]``, ``frag_idx`` = the token's index,
``FLAG_LAST_FRAGMENT`` on the final token — so the client reassembles
the full generation exactly like ``repro.core.reassembly`` orders any
other >MTU RPC.  A rejected request gets a NACK (RESPONSE |
LAST_FRAGMENT, token -1) so the client side can account every arrival.

**Slot lifecycle** (``DecodeSlots``, all updates inside the fused step):

  free (req_id = -1)
    -> admitted   argsort free-list, same idiom as ``ServingEngine``;
                  arrivals beyond the free count are REJECTED + NACKed
    -> prompt     pos < prompt_len-1: feed prompt_token(seed, pos+1),
                  always advances (prompt tokens are local, no egress)
    -> generate   decode output feeds back; the token response must be
                  ACCEPTED by the TX ring to advance — a full ring
                  stalls the slot (backpressure), and the stalled step
                  recomputes bit-identical state (same pos, same token,
                  idempotent cache row write)
    -> free       the step the LAST token's response is accepted —
                  freed slots are re-admissible THE SAME STEP.

Conservation (pinned by tests):  ``admitted == completed + active +
rejected`` where ``active = #(req_id >= 0)`` — every request that ever
reached admission is in exactly one bucket.

**Telemetry unit contract** (per-tenant ``Telemetry`` pair):
  * TTFT — observed when the FIRST generated token's response is
    accepted, against the request's injection stamp:
    ``ttft = accept_step - inject_step + 1`` fabric steps.  Uncongested,
    a prompt of P tokens gives exactly ``P + 1`` (admission step +
    P decode steps).
  * ITL — observed on every subsequent accepted token against the
    previous accepted emission: consecutive-step streaming gives
    exactly 1; backpressure and scheduling gaps show up as >1.
Both counters tick once per fused step, aligned with the generator's
step stamp (thread fresh states together).

**2-D mesh**: ``make_sharded_run_steps`` shard_maps the whole loop over
a (tenant, model) grid — tenants (fabric + slots + generator) shard the
tenant axis; each tenant's weights and KV-cache kv-head dim shard the
model axis per ``parallel.sharding`` with ``lax.psum`` partial-sum
reduction inside the model (``ModelConfig.tp_axis``).  Fabric state is
replicated over the model axis and every replica computes the identical
deterministic dataplane, so egress tiles agree replica-to-replica.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import FabricConfig, ModelConfig
from repro.core import loadgen as lg
from repro.core import serdes
from repro.core import telemetry as tlm
from repro.core.fabric import DaggerFabric
from repro.core.load_balancer import LB_ROUND_ROBIN
from repro.models import Model

_SALT_SEED = 11       # request seed   = hash(lane key, rpc_id, salt)
_SALT_PLEN = 12       # prompt length
_SALT_MNEW = 13       # max new tokens
_SALT_PROMPT = 14     # prompt token j = hash(request seed, j, salt)


def prompt_token(seed, j, vocab: int):
    """Token ``j`` of the prompt named by ``seed`` — a pure counter-PRNG
    hash, so client, server and oracle all derive identical prompts
    without the prompt ever crossing the wire."""
    return (lg.counter_hash(seed, j, _SALT_PROMPT)
            % jnp.uint32(vocab)).astype(jnp.int32)


@jax.tree_util.register_dataclass
@dataclass
class DecodeSlots:
    """The decode pool: one row per slot, all int32 (vmap/shard/donate
    like every carry pytree).  ``req_id < 0`` marks a free slot."""
    req_id: jnp.ndarray      # [N] admitted request id (-1 = free)
    conn: jnp.ndarray        # [N] connection to respond on
    flow: jnp.ndarray        # [N] origin flow (response TX ring)
    tstamp: jnp.ndarray      # [N] injection step (TTFT reference)
    seed: jnp.ndarray        # [N] prompt seed
    prompt_len: jnp.ndarray  # [N] prompt length (>= 1)
    max_new: jnp.ndarray     # [N] tokens to generate (>= 1)
    pos: jnp.ndarray         # [N] decode position (cache row in use)
    tok: jnp.ndarray         # [N] token fed to the next decode step
    emitted: jnp.ndarray     # [N] accepted generated-token responses
    last_emit: jnp.ndarray   # [N] step of the previous acceptance (ITL)
    admitted: jnp.ndarray    # scalar: arrivals that reached admission
    completed: jnp.ndarray   # scalar: requests fully streamed + freed
    rejected: jnp.ndarray    # scalar: arrivals NACKed (pool full)


@jax.tree_util.register_dataclass
@dataclass
class DecodeStates:
    """Everything one decode tenant carries through the fused loop."""
    cst: object              # client FabricState
    sst: object              # server FabricState
    gst: object              # LoadGenState (open-loop request source)
    slots: DecodeSlots
    cache: object            # KV cache pytree [N, S, ...]
    ttft: tlm.Telemetry      # time-to-first-token histogram
    itl: tlm.Telemetry       # inter-token-latency histogram


def _slots_init(n: int) -> DecodeSlots:
    z = jnp.zeros((n,), jnp.int32)
    s = jnp.int32(0)
    return DecodeSlots(req_id=jnp.full((n,), -1, jnp.int32), conn=z,
                       flow=z, tstamp=z, seed=z,
                       prompt_len=jnp.ones((n,), jnp.int32),
                       max_new=jnp.ones((n,), jnp.int32), pos=z, tok=z,
                       emitted=z, last_emit=z, admitted=s, completed=s,
                       rejected=s)


def default_fabric_config(**overrides) -> FabricConfig:
    """The decode tenant's fabric: ``dynamic_batching=False`` is
    REQUIRED — the NIC scheduler's batching gate would otherwise hold a
    lone request in its flow FIFO forever (no co-flow traffic to fill
    the batch), deadlocking low-rate decode."""
    kw = dict(n_flows=2, ring_entries=64, batch_size=4,
              dynamic_batching=False)
    kw.update(overrides)
    return FabricConfig(**kw)


class DecodeEngine:
    """Continuous-batching decode service behind a client/server fabric
    pair, fed by the open-loop generator.

    ``n_slots`` bounds concurrent requests; prompts draw lengths in
    ``[1, max_prompt]`` and generations in ``[1, max_new_cap]``, so
    ``max_prompt + max_new_cap <= max_seq`` bounds the cache."""

    def __init__(self, cfg: ModelConfig, fabric_cfg: FabricConfig = None,
                 n_slots: int = 4, max_prompt: int = 4,
                 max_new_cap: int = 4, max_seq: Optional[int] = None,
                 mode: int = lg.MODE_POISSON, params=None, seed: int = 0,
                 n_bins: int = tlm.LAT_BINS):
        if cfg.enc_layers or cfg.mtp_depth or cfg.frontend:
            raise ValueError("decode tenant serves decoder-only LMs")
        self.cfg = cfg
        self.model = Model(cfg)
        fabric_cfg = fabric_cfg or default_fabric_config()
        if fabric_cfg.dynamic_batching:
            raise ValueError(
                "decode tenant needs dynamic_batching=False fabrics — "
                "the NIC batching gate deadlocks single requests")
        self.client = DaggerFabric(fabric_cfg)
        self.server = DaggerFabric(fabric_cfg)
        self.n_slots = int(n_slots)
        self.max_prompt = int(max_prompt)
        self.max_new_cap = int(max_new_cap)
        self.max_seq = int(max_seq if max_seq is not None else cfg.max_seq)
        if self.max_prompt + self.max_new_cap > self.max_seq:
            raise ValueError("max_prompt + max_new_cap must fit max_seq")
        self.n_bins = int(n_bins)
        self.pw = self.client.slot_words - serdes.HEADER_WORDS
        if self.pw < 4:
            raise ValueError("request payload needs >= 4 words")
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)
        self.loadgen = lg.LoadGen(self.client, mode=mode,
                                  payload_fn=self._request_payload)

    # ------------------------------------------------------------ requests
    def _request_payload(self, gst, lane, rpc_id):
        """LoadGen payload hook: encode (req_id, seed, plen, max_new) —
        all pure hashes of the lane key and rpc_id, so a request's
        content is independent of WHEN it arrives (the request-level
        differential tests lean on this)."""
        # sign-bit clamp on a PRNG draw (payload word, not a header
        # wire field): # fabriclint: allow(FL004)
        seed = (lg.counter_hash(gst.key, rpc_id, _SALT_SEED)
                & jnp.uint32(0x7FFFFFFF)).astype(jnp.int32)
        plen = 1 + (lg.counter_hash(gst.key, rpc_id, _SALT_PLEN)
                    % jnp.uint32(self.max_prompt)).astype(jnp.int32)
        mnew = 1 + (lg.counter_hash(gst.key, rpc_id, _SALT_MNEW)
                    % jnp.uint32(self.max_new_cap)).astype(jnp.int32)
        pay = jnp.zeros((lane.shape[0], self.pw), jnp.int32)
        pay = pay.at[:, 0].set(rpc_id).at[:, 1].set(seed)
        pay = pay.at[:, 2].set(plen).at[:, 3].set(mnew)
        return pay

    # --------------------------------------------------------------- state
    def init_states(self, rate: float, seed: int = 0,
                    conn: int = 1) -> DecodeStates:
        cst = self.client.init_state()
        sst = self.server.init_state()
        cst = self.client.open_connection(cst, conn, 0, 1, LB_ROUND_ROBIN)
        sst = self.server.open_connection(sst, conn, 0, 0, LB_ROUND_ROBIN)
        return DecodeStates(
            cst=cst, sst=sst,
            gst=self.loadgen.init_state(rate, seed=seed, conn=conn),
            slots=_slots_init(self.n_slots),
            cache=self.model.cache_init(self.n_slots, self.max_seq),
            ttft=tlm.create(self.n_bins), itl=tlm.create(self.n_bins))

    def init_states_batch(self, rates, seeds=None) -> DecodeStates:
        """Stacked per-tenant states (leading tenant axis) — tenant i
        offers ``rates[i]`` with its own generator key."""
        from repro.core.engine import stack_states
        seeds = list(range(len(rates))) if seeds is None else list(seeds)
        return stack_states([self.init_states(r, seed=s)
                             for r, s in zip(rates, seeds)])

    # ---------------------------------------------------------- serve step
    def _make_serve_step(self, model: Model = None):
        """Server half of the fused step: deliver -> decode pool ->
        stream tokens -> free -> admit -> NACK -> egress fetch.

        ``(sst, slots, cache, ttft, itl, params, in_slots, in_valid) ->
        (sst, slots, cache, ttft, itl, out_slots, out_valid)``."""
        model = model or self.model
        fab, n = self.server, self.n_slots
        vocab, pw = self.cfg.vocab, self.pw

        def step(sst, slots: DecodeSlots, cache, ttft, itl, params,
                 in_slots, in_valid):
            step_now = ttft.step
            # 1. wire -> NIC: deliver arrivals through the server NIC
            sst, recs, rvalid = fab.nic_pipeline(sst, in_slots, in_valid)
            req = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                               recs)
            rv = rvalid.reshape(-1)
            is_req = rv & ((req["flags"] & serdes.FLAG_RESPONSE) == 0)

            # 2. decode the WHOLE pool at per-slot positions (continuous
            # batching: slots at different depths share the step).  Free
            # slots decode garbage rows they never advance past; those
            # rows are rewritten before any admitted request attends
            # them, so they are unobservable.
            active = slots.req_id >= 0
            logits, cache = model.decode_step(params, cache,
                                              slots.tok[:, None],
                                              slots.pos)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

            in_prompt = slots.pos < slots.prompt_len - 1
            gen = active & ~in_prompt
            first = gen & (slots.emitted == 0)
            last = gen & (slots.emitted + 1 >= slots.max_new)

            # 3. stream: each token is one fragment of the >MTU response
            pay = jnp.zeros((n, pw), jnp.int32)
            pay = pay.at[:, 0].set(slots.req_id).at[:, 1].set(nxt)
            pay = pay.at[:, 2].set(slots.emitted).at[:, 3].set(
                slots.tstamp)
            flags = (serdes.FLAG_RESPONSE | serdes.FLAG_FRAGMENT
                     | jnp.where(last, serdes.FLAG_LAST_FRAGMENT, 0)
                     | (slots.flow << 8))
            out = serdes.make_records(slots.conn, slots.req_id,
                                      jnp.zeros((n,), jnp.int32), flags,
                                      pay, frag_idx=slots.emitted,
                                      timestamp=slots.tstamp)
            sst, acc = fab.host_tx_enqueue(sst, out, slots.flow, gen)
            acc = acc & gen

            # 4. telemetry at the acceptance edge (the egress decision)
            ttft = tlm.observe(ttft, slots.tstamp, acc & first)
            itl = tlm.observe(itl, slots.last_emit + 1,
                              acc & (slots.emitted > 0))

            # 5. advance: prompt feeding is unconditional, generation
            # only on acceptance (a full TX ring stalls the slot; the
            # retried step recomputes identical state)
            adv = active & (in_prompt | acc)
            tok2 = jnp.where(
                adv, jnp.where(in_prompt,
                               prompt_token(slots.seed, slots.pos + 1,
                                            vocab), nxt), slots.tok)
            pos2 = slots.pos + adv.astype(jnp.int32)
            emitted2 = slots.emitted + acc.astype(jnp.int32)
            last_emit2 = jnp.where(acc, step_now, slots.last_emit)

            # 6. free finished slots — re-admissible this same step
            done = acc & last
            req_id2 = jnp.where(done, -1, slots.req_id)
            completed = slots.completed + jnp.sum(done.astype(jnp.int32))

            # 7. admission: argsort free-list (ServingEngine idiom);
            # arrivals ranked first-free-first, overflow rejected
            free = req_id2 < 0
            order = jnp.argsort(jnp.where(free, jnp.arange(n), n + 1))
            n_free = jnp.sum(free.astype(jnp.int32))
            rank = jnp.cumsum(is_req.astype(jnp.int32)) - 1
            ok = is_req & (rank < n_free)
            slot = order[jnp.clip(rank, 0, n - 1)]
            slot_safe = jnp.where(ok, slot, n)        # OOB rows drop

            r_seed = req["payload"][:, 1]
            r_plen = jnp.clip(req["payload"][:, 2], 1, self.max_prompt)
            r_mnew = jnp.clip(req["payload"][:, 3], 1, self.max_new_cap)
            r_flow = (req["flags"] >> 8) & 0xFF
            sca = lambda dst, val: dst.at[slot_safe].set(val, mode="drop")
            slots2 = DecodeSlots(
                req_id=sca(req_id2, req["payload"][:, 0]),
                conn=sca(slots.conn, req["conn_id"]),
                flow=sca(slots.flow, r_flow),
                tstamp=sca(slots.tstamp, req["timestamp"]),
                seed=sca(slots.seed, r_seed),
                prompt_len=sca(slots.prompt_len, r_plen),
                max_new=sca(slots.max_new, r_mnew),
                pos=sca(pos2, jnp.zeros_like(r_plen)),
                tok=sca(tok2, prompt_token(r_seed, 0, vocab)),
                emitted=sca(emitted2, jnp.zeros_like(r_plen)),
                last_emit=sca(last_emit2, jnp.full_like(r_plen,
                                                        step_now)),
                admitted=slots.admitted + jnp.sum(
                    is_req.astype(jnp.int32)),
                completed=completed,
                rejected=slots.rejected + jnp.sum(
                    (is_req & ~ok).astype(jnp.int32)))

            # 8. NACK rejections so the client can account every arrival
            rej = is_req & ~ok
            npay = jnp.zeros((rv.shape[0], pw), jnp.int32)
            npay = npay.at[:, 0].set(req["payload"][:, 0])
            npay = npay.at[:, 1].set(-1)
            nack = serdes.make_records(
                req["conn_id"], req["rpc_id"],
                jnp.zeros_like(req["rpc_id"]),
                serdes.FLAG_RESPONSE | serdes.FLAG_LAST_FRAGMENT
                | (r_flow << 8), npay, timestamp=req["timestamp"])
            sst, _ = fab.host_tx_enqueue(sst, nack, r_flow, rej)

            ttft = tlm.tick(ttft)
            itl = tlm.tick(itl)
            # 9. NIC -> wire: fetch the token stream off the TX rings
            sst, out_slots, out_valid = fab.nic_fetch(sst)
            w = out_slots.shape[-1]
            return (sst, slots2, cache, ttft, itl,
                    out_slots.reshape(-1, w), out_valid.reshape(-1))

        return step

    def make_decode_step(self, model: Model = None):
        """The full fused tenant step: ``(DecodeStates, params) ->
        (DecodeStates, (comp_slots [N, W], comp_valid [N]))`` — the
        ys are the client-delivered token fragments, packed."""
        serve = self._make_serve_step(model)
        gen, client = self.loadgen, self.client

        def step(st: DecodeStates, params):
            cst, gst = gen.inject(st.cst, st.gst)
            cst, cl_slots, cl_valid = client.nic_fetch(cst)
            w = cl_slots.shape[-1]
            sst, slots, cache, ttft, itl, sv_out, sv_valid = serve(
                st.sst, st.slots, st.cache, st.ttft, st.itl, params,
                cl_slots.reshape(-1, w), cl_valid.reshape(-1))
            cst, crecs, cvalid = client.nic_pipeline(cst, sv_out,
                                                     sv_valid)
            flat = jax.tree.map(
                lambda x: x.reshape((-1,) + x.shape[2:]), crecs)
            comp = serdes.pack(flat, client.slot_words)
            st = DecodeStates(cst, sst, gst, slots, cache, ttft, itl)
            return st, (comp, cvalid.reshape(-1))

        return step

    # -------------------------------------------------------- entry points
    def make_run_steps(self, n_steps: int):
        """Scan-fused single-tenant loop: ``run(st, params) -> (st,
        (comp_slots [K, N, W], comp_valid [K, N]))`` — K steps, ONE
        dispatch, states donated."""
        step = self.make_decode_step()

        def run(st, params):
            return jax.lax.scan(lambda c, _: step(c, params), st, None,
                                length=n_steps)

        fn = jax.jit(run, donate_argnums=(0,))

        def wrapped(st, params=None):
            from repro.core.engine import unalias
            params = self.params if params is None else params
            st = unalias(st, protected=(params,))
            return fn(st, params)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        return wrapped

    def make_tenant_run_steps(self, n_steps: int):
        """Tenant-batched loop (vmap over the leading tenant axis,
        shared weights): states from ``init_states_batch``; ys come
        back ``[K, T, N, ...]``."""
        vstep = jax.vmap(self.make_decode_step(), in_axes=(0, None))

        def run(st, params):
            return jax.lax.scan(lambda c, _: vstep(c, params), st, None,
                                length=n_steps)

        fn = jax.jit(run, donate_argnums=(0,))

        def wrapped(st, params=None):
            from repro.core.engine import unalias
            params = self.params if params is None else params
            st = unalias(st, protected=(params,))
            return fn(st, params)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        return wrapped

    def make_sharded_run_steps(self, mesh, n_steps: int):
        """2-D (tenant x model) mesh loop: tenants shard the tenant
        axis; weights and KV-cache kv-heads shard the model axis
        (tensor parallelism via ``ModelConfig.tp_axis`` -> in-model
        ``lax.psum``).  Fabric/generator/telemetry states are
        replicated over the model axis — every replica runs the same
        deterministic dataplane.  Same signature/returns as
        ``make_tenant_run_steps``; the tenant count must divide the
        tenant axis."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.debug import sanitize
        from repro.parallel.sharding import (decode_cache_specs,
                                             legalize_specs, param_specs)

        sanitize.note_unsanitized_sharded("DecodeEngine (sharded)")

        t_axis, m_axis = mesh.axis_names
        mp = int(mesh.shape[m_axis])
        cfg = self.cfg
        if mp > 1:
            bad = [nm for nm, d in (("n_heads", cfg.n_heads),
                                    ("n_kv_heads", cfg.n_kv_heads),
                                    ("d_ff", cfg.d_ff),
                                    ("vocab", cfg.vocab)) if d % mp]
            if bad:
                raise ValueError(
                    f"tensor parallelism over {mp} devices needs "
                    f"{bad} divisible by {mp}")
            if cfg.attn_kind != "gqa" or cfg.moe is not None:
                raise ValueError("TP decode path requires dense GQA")
            model = Model(dataclasses.replace(cfg, tp_axis=m_axis))
        else:
            model = self.model
        vstep = jax.vmap(self.make_decode_step(model), in_axes=(0, None))

        def local(st, params):
            return jax.lax.scan(lambda c, _: vstep(c, params), st, None,
                                length=n_steps)

        def run(st, params):
            sspec = jax.tree.map(
                lambda x: P(t_axis) if jnp.ndim(x) else P(), st)
            sspec = dataclasses.replace(
                sspec, cache=decode_cache_specs(
                    cfg, st.cache, mesh, tenant_axis=t_axis,
                    tp_axis=m_axis))
            pspec = legalize_specs(
                param_specs(cfg, params, tp=m_axis, fsdp=False), params,
                mesh)
            tile = P(None, t_axis)
            return shard_map(local, mesh=mesh, in_specs=(sspec, pspec),
                             out_specs=(sspec, (tile, tile)),
                             check_rep=False)(st, params)

        fn = jax.jit(run, donate_argnums=(0,))

        def wrapped(st, params=None):
            from repro.core.engine import unalias
            params = self.params if params is None else params
            t = st.slots.req_id.shape[0]
            if t % int(mesh.shape[t_axis]):
                raise ValueError(
                    f"n_tenants={t} must divide over the "
                    f"{mesh.shape[t_axis]}-device '{t_axis}' axis")
            st = unalias(st, protected=(params,))
            return fn(st, params)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        return wrapped


# --------------------------------------------------------------- host side
def collect_streams(comp_slots, comp_valid):
    """Reassemble the client-delivered token fragments host-side.

    ``comp_slots``: [..., N, W] packed egress tiles (any leading step /
    tenant dims), ``comp_valid`` matching [..., N].  Returns
    ``{req_id: {"tokens": [...], "done": bool, "nack": bool}}`` with
    tokens in fragment order — the >MTU reassembly contract applied to
    generation streams."""
    import numpy as np
    recs = serdes.unpack(jnp.asarray(comp_slots))
    flat = {k: np.asarray(jax.device_get(v)).reshape(
        (-1,) + (v.shape[-1:] if k == "payload" else ()))
        for k, v in recs.items()}
    valid = np.asarray(jax.device_get(comp_valid)).reshape(-1) != 0
    out = {}
    for i in np.nonzero(valid)[0]:
        flags = int(flat["flags"][i])
        if not flags & serdes.FLAG_RESPONSE:
            continue
        rid = int(flat["payload"][i][0])
        ent = out.setdefault(rid, {"frags": {}, "done": False,
                                   "nack": False})
        if flags & serdes.FLAG_FRAGMENT:
            ent["frags"][int(flat["frag_idx"][i])] = \
                int(flat["payload"][i][1])
        elif flags & serdes.FLAG_LAST_FRAGMENT:
            ent["nack"] = True
        if flags & serdes.FLAG_LAST_FRAGMENT:
            ent["done"] = True
    for ent in out.values():
        ent["tokens"] = [ent["frags"][j] for j in sorted(ent["frags"])]
        del ent["frags"]
    return out
