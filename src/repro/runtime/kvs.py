"""MICA-style in-device key-value store (paper §5.6 backend).

A set-associative, lossy hash index: [n_buckets, ways] tag array + full
key/value stores, batched vectorized GET/SET, eviction by hash-picked way
(MICA's lossy mode).  Keys are steered to partitions (flows) by the
object-level load balancer *before* reaching the store — the Dagger NIC's
job — so each lane only ever touches its own partition (MICA's
core-partitioned design; here lane-partitioned).

The GET probe has a Pallas kernel (``repro.kernels.kv_probe``); the jnp
path below is the oracle and the default on CPU.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.load_balancer import fnv1a_words


@jax.tree_util.register_dataclass
@dataclass
class KVSState:
    tags: jnp.ndarray        # [NB, WAYS] uint32, 0 = empty
    keys: jnp.ndarray        # [NB, WAYS, KW] int32
    vals: jnp.ndarray        # [NB, WAYS, VW] int32
    n_set: jnp.ndarray
    n_get: jnp.ndarray
    n_hit: jnp.ndarray
    n_evict: jnp.ndarray


class DeviceKVS:
    def __init__(self, n_buckets: int = 1024, ways: int = 4,
                 key_words: int = 2, value_words: int = 8,
                 use_pallas: bool = False):
        self.nb = n_buckets
        self.ways = ways
        self.kw = key_words
        self.vw = value_words
        self.use_pallas = use_pallas

    def init_state(self) -> KVSState:
        z = jnp.int32(0)
        return KVSState(
            tags=jnp.zeros((self.nb, self.ways), jnp.uint32),
            keys=jnp.zeros((self.nb, self.ways, self.kw), jnp.int32),
            vals=jnp.zeros((self.nb, self.ways, self.vw), jnp.int32),
            n_set=z, n_get=z, n_hit=z, n_evict=z)

    def init_state_batch(self, n_tenants: int) -> KVSState:
        """Stacked per-tenant stores (leading tenant axis) for the
        tenant-batched engine — each tenant owns an isolated partition
        set, mirroring MICA's per-core partitions across NIC slots."""
        from repro.core.engine import stack_states
        return stack_states([self.init_state() for _ in range(n_tenants)])

    # ------------------------------------------------------------------
    def _bucket_tag(self, key_words):
        h = fnv1a_words(key_words, self.kw)
        bucket = (h % jnp.uint32(self.nb)).astype(jnp.int32)
        tag = (h | jnp.uint32(1))                   # nonzero tag
        return bucket, tag, h

    def get(self, st: KVSState, key_words, valid=None):
        """key_words: [N, KW] -> (values [N, VW], hit [N])."""
        n = key_words.shape[0]
        valid = jnp.ones((n,), bool) if valid is None else valid
        bucket, tag, _ = self._bucket_tag(key_words)
        if self.use_pallas:
            from repro.kernels import ops
            val, tag_hit = ops.kv_probe(st.tags, st.vals, bucket, tag)
            bk = st.keys[bucket]                    # key verify (anti-alias)
            way = self._match_way(st, bucket, tag, key_words)[1]
            key_ok = jnp.all(bk[jnp.arange(n), way] == key_words, axis=-1)
            hit = tag_hit & key_ok & valid
        else:
            match, way = self._match_way(st, bucket, tag, key_words)
            hit = jnp.any(match, axis=1) & valid
            val = st.vals[bucket, way]
        val = jnp.where(hit[:, None], val, 0)
        st2 = _bump(st, n_get=jnp.sum(valid.astype(jnp.int32)),
                    n_hit=jnp.sum(hit.astype(jnp.int32)))
        return st2, val, hit

    def set(self, st: KVSState, key_words, val_words, valid=None):
        """Insert/update [N] records (in-batch duplicate order undefined)."""
        n = key_words.shape[0]
        valid = jnp.ones((n,), bool) if valid is None else valid
        bucket, tag, h = self._bucket_tag(key_words)
        match, way_m = self._match_way(st, bucket, tag, key_words)
        exists = jnp.any(match, axis=1)
        empty = st.tags[bucket] == 0                # [N, WAYS]
        has_empty = jnp.any(empty, axis=1)
        way_e = jnp.argmax(empty, axis=1)
        way_v = ((h >> jnp.uint32(16)) % jnp.uint32(self.ways)).astype(jnp.int32)
        way = jnp.where(exists, way_m, jnp.where(has_empty, way_e, way_v))
        evictions = valid & ~exists & ~has_empty
        b = jnp.where(valid, bucket, self.nb)       # OOB -> drop
        tags = st.tags.at[b, way].set(tag, mode="drop")
        keys = st.keys.at[b, way].set(key_words, mode="drop")
        vals = st.vals.at[b, way].set(val_words, mode="drop")
        st2 = KVSState(tags, keys, vals, st.n_set, st.n_get, st.n_hit,
                       st.n_evict)
        return _bump(st2, n_set=jnp.sum(valid.astype(jnp.int32)),
                     n_evict=jnp.sum(evictions.astype(jnp.int32)))

    def _match_way(self, st, bucket, tag, key_words):
        bt = st.tags[bucket]                        # [N, WAYS]
        bk = st.keys[bucket]                        # [N, WAYS, KW]
        match = (bt == tag[:, None]) & jnp.all(
            bk == key_words[:, None, :], axis=-1)
        return match, jnp.argmax(match, axis=1)

    # ------------------------------------------------- fabric integration
    def make_handler(self):
        """Returns handler(payload [N,W], valid [N], state) for the fabric.

        fn_id 0 = GET (payload: key), 1 = SET (payload: key ++ value).
        Response payload: [status, value...] (status 1 = hit/stored)."""
        kw, vw = self.kw, self.vw

        def handler(payload, valid, st, fn_id):
            key = payload[:, :kw]
            val_in = payload[:, kw:kw + vw]
            is_set = fn_id == 1
            st = self.set(st, key, val_in, valid & is_set)
            st, val, hit = self.get(st, key, valid & ~is_set)
            status = jnp.where(is_set, 1, hit.astype(jnp.int32))
            out = jnp.zeros_like(payload)
            out = out.at[:, 0].set(status)
            out = out.at[:, 1:1 + vw].set(jnp.where(is_set[:, None],
                                                    val_in, val))
            return out, st

        return handler

    def make_engine(self, client, server):
        """Scan-fused loopback engine serving this store (paper §5.6).

        The KVSState is the engine's handler state: GET/SET handling,
        steering and the store update all stay inside the fused device
        step, and the steady-state loop runs K iterations per host
        dispatch (``engine.run_steps(cst, sst, k, hstate=db)``).

        Per-op latency telemetry rides the same carry: pass
        ``tel=telemetry.create()`` (clients stamp request records with
        the step counter via ``serdes.make_records(...,
        timestamp=...)``) and the returned Telemetry histogram holds
        every GET/SET's fabric residency in steps — the paper's
        Fig. 12 µs medians come from this histogram times the measured
        step cost, not from a host wall clock.
        """
        from repro.core.engine import LoopbackEngine
        return LoopbackEngine(client, server, self._record_handler(),
                              stateful=True)

    def make_tenant_engine(self, client, server):
        """Tenant-batched KVS engine (one NIC slot + store per tenant).

        ``engine.run_steps(csts, ssts, k, hstate=dbs)`` drives N
        independent client/server/store triples in one dispatch;
        ``dbs`` is ``init_state_batch(n)`` (or any stacked KVSState).
        Bit-identical to N separate ``make_engine`` runs.
        """
        from repro.core.engine import TenantEngine
        return TenantEngine(client, server, self._record_handler(),
                            stateful=True)

    def make_sharded_tenant_engine(self, client, server, mesh=None,
                                   axis: str = "tenant"):
        """Mesh-sharded KVS engine: each device owns whole NIC slots —
        client/server pairs AND their tenant stores — and runs the fused
        GET/SET loop device-local (MICA's core partitioning lifted to the
        mesh).  Call ``engine.shard_states(csts, ssts, dbs)`` (placement
        via ``parallel.sharding.legalize_specs``) before the first
        ``run_steps``; results are bit-identical to
        ``make_tenant_engine`` on any mesh shape.

        The returned engine also exposes
        ``run_until_global(csts, ssts, global_target, max_steps,
        hstate=dbs)``: a fleet-wide completion sweep whose while
        predicate is a ``psum`` over per-device done counters, so
        devices whose stores drained early keep pumping until the whole
        fleet has served ``global_target`` GET/SET RPCs — returns
        ``(csts, ssts, dbs, n_done [T], dev_steps [D])``; with
        ``tel=telemetry.create_batch(T)`` it additionally returns the
        per-tenant Telemetry and the psum-merged fleet-wide latency
        histogram (bit-identical to the single-device run on any mesh
        shape).
        """
        from repro.core.engine import ShardedTenantEngine
        return ShardedTenantEngine(client, server, self._record_handler(),
                                   mesh=mesh, axis=axis, stateful=True)

    def _record_handler(self):
        h = self.make_handler()

        def handler(recs, valid, db):
            pay, db = h(recs["payload"], valid, db, recs["fn_id"])
            out = dict(recs)
            out["payload"] = pay
            return out, db

        return handler


def _bump(st: KVSState, **kw):
    import dataclasses
    return dataclasses.replace(
        st, **{k: getattr(st, k) + v for k, v in kw.items()})
