"""ServingEngine: LM serving *through* the Dagger fabric.

This is the paper's thesis applied to model serving: the entire request
dataplane — ring drain, session lookup (the connection-manager analogue),
steering, batching, the decode step itself, sampling, and response
enqueue — runs as ONE fused device step.  The host's per-request work is
a single ring write (``request()``), exactly Dagger's "single memory
write in the critical RPC path".

Request wire format (payload words):
  [0] session_id    (client-chosen, pins the stream: static LB/affinity)
  [1] token         (next prompt token, or -1 = "sample for me")
  [2] flags         (bit0: NEW session)
Response payload:
  [0] session_id  [1] next_token  [2] position

Sessions own a *slot* (row) of the decode batch + KV cache; per-slot
positions make this continuous batching — streams at different depths
decode in the same step.  Slot allocation/lookup is vectorized (argsort
free-list + match matrix), mirroring the connection cache's role.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FabricConfig, ModelConfig
from repro.core import serdes
from repro.core import telemetry as tlm
from repro.core.fabric import DaggerFabric, FabricState
from repro.models import Model

FLAG_NEW = 1


@jax.tree_util.register_dataclass
@dataclass
class SessionState:
    session_id: jnp.ndarray     # [Nslots] int32, -1 = free
    pos: jnp.ndarray            # [Nslots] int32 next decode position
    last_token: jnp.ndarray     # [Nslots] int32


class ServingEngine:
    def __init__(self, cfg: ModelConfig, fabric_cfg: FabricConfig,
                 n_slots: int, max_seq: int, params=None, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.fabric = DaggerFabric(fabric_cfg)
        self.n_slots = n_slots
        self.max_seq = max_seq
        key = jax.random.PRNGKey(seed)
        self.params = params if params is not None else self.model.init(key)

    def init_states(self):
        fst = self.fabric.init_state()
        cache = self.model.cache_init(self.n_slots, self.max_seq)
        sess = SessionState(jnp.full((self.n_slots,), -1, jnp.int32),
                            jnp.zeros((self.n_slots,), jnp.int32),
                            jnp.zeros((self.n_slots,), jnp.int32))
        return fst, cache, sess

    # ------------------------------------------------------------------
    def make_serve_step(self):
        """The fused dataplane+model step (server side).

        (fabric_state, cache, sessions, params, in_slots, in_valid)
          -> (fabric_state, cache, sessions, served, out_slots, out_valid)

        ``in_*`` is the wire-ingress tile (requests arriving from client
        NICs / the switch); ``out_*`` is the wire-egress tile (responses
        fetched from the server TX rings).  The whole body — deliver,
        steer, batch, session lookup, decode, sample, respond — is one
        device step."""
        model, fab, n_slots = self.model, self.fabric, self.n_slots

        def step(fst: FabricState, cache, sess: SessionState, params,
                 in_slots, in_valid):
            # 1. wire -> NIC: request buffer, steer, flow FIFOs, RX rings
            fst, recs, rvalid = fab.nic_pipeline(fst, in_slots, in_valid)
            req = jax.tree.map(lambda x: x.reshape((-1,) + x.shape[2:]),
                               recs)
            rv = rvalid.reshape(-1)                        # [N]
            sid = req["payload"][:, 0]
            tok_in = req["payload"][:, 1]
            is_new = (req["payload"][:, 2] & FLAG_NEW) != 0

            # 2. session lookup (connection-manager analogue)
            match = (sid[:, None] == sess.session_id[None, :]) \
                & (sess.session_id[None, :] >= 0)           # [N, Nslots]
            has_slot = jnp.any(match, axis=1)
            slot_of = jnp.argmax(match, axis=1)
            # allocate free slots to NEW sessions (rank -> kth free slot)
            free = sess.session_id < 0
            order = jnp.argsort(jnp.where(free, jnp.arange(n_slots),
                                          n_slots + 1))
            n_free = jnp.sum(free.astype(jnp.int32))
            want_new = rv & is_new & ~has_slot
            rank = jnp.cumsum(want_new.astype(jnp.int32)) - 1
            alloc_ok = want_new & (rank < n_free)
            new_slot = order[jnp.clip(rank, 0, n_slots - 1)]
            slot = jnp.where(alloc_ok, new_slot, slot_of)
            active_req = rv & (alloc_ok | has_slot)
            slot_safe = jnp.where(active_req, slot, n_slots)  # OOB drop

            # 3. update session table + stage tokens
            sess_id2 = sess.session_id.at[slot_safe].set(sid, mode="drop")
            pos2 = sess.pos.at[slot_safe].set(
                jnp.where(alloc_ok, 0, sess.pos.at[slot_safe].get(
                    mode="fill", fill_value=0)), mode="drop")
            tok_stage = sess.last_token.at[slot_safe].set(
                jnp.where(tok_in >= 0, tok_in,
                          sess.last_token.at[slot_safe].get(
                              mode="fill", fill_value=0)), mode="drop")
            slot_has_req = jnp.zeros((n_slots,), bool).at[slot_safe].set(
                True, mode="drop")

            # 4. decode every active slot at its own position
            tokens = tok_stage[:, None]                     # [Nslots, 1]
            logits, cache2 = model.decode_step(params, cache, tokens, pos2)
            next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)

            run = slot_has_req
            sess2 = SessionState(
                sess_id2,
                jnp.where(run, pos2 + 1, pos2),
                jnp.where(run, next_tok, tok_stage))
            # only slots that ran keep their cache writes; others keep old
            # (the decode wrote at pos2 rows regardless — harmless, those
            # rows' pos pointer did not advance)

            # 5. responses: [sid, next_token, position] back through fabric
            n = rv.shape[0]
            pw = fab.slot_words - serdes.HEADER_WORDS
            resp_payload = jnp.zeros((n, pw), jnp.int32)
            resp_payload = resp_payload.at[:, 0].set(sid)
            resp_payload = resp_payload.at[:, 1].set(
                next_tok.at[slot_safe].get(mode="fill", fill_value=-1))
            resp_payload = resp_payload.at[:, 2].set(
                pos2.at[slot_safe].get(mode="fill", fill_value=-1))
            resp = dict(req)
            resp["payload"] = resp_payload
            resp["flags"] = req["flags"] | serdes.FLAG_RESPONSE
            flow_of = jnp.repeat(
                jnp.arange(fab.cfg.n_flows, dtype=jnp.int32),
                fab.cfg.batch_size)
            fst, _ = fab.host_tx_enqueue(fst, resp, flow_of, active_req)
            served = jnp.sum(active_req.astype(jnp.int32))
            # 6. NIC -> wire: responses leave through the TX path
            fst, out_slots, out_valid = fab.nic_fetch(fst)
            w = out_slots.shape[-1]
            return (fst, cache2, sess2, served,
                    out_slots.reshape(-1, w), out_valid.reshape(-1))

        return step

    def make_serve_step_telemetry(self):
        """The fused serve step with latency telemetry threaded through.

        ``tstep(fst, cache, sess, tel, params, in_slots, in_valid)``
        wraps ``make_serve_step``: the egress tile's RESPONSES —
        requests served and put back on the wire this step — are
        observed against their stamped issue step (clients stamp
        ``serdes`` word 4 with the telemetry step counter), then the
        step counter ticks.  Residency therefore covers the whole NIC
        path: deliver, flow FIFOs, decode, respond, TX fetch.
        Returns ``(fst, cache, sess, tel, served, out_slots,
        out_valid)``.
        """
        step = self.make_serve_step()

        def tstep(fst, cache, sess, tel, params, in_slots, in_valid):
            fst, cache, sess, served, out_s, out_v = step(
                fst, cache, sess, params, in_slots, in_valid)
            recs = serdes.unpack(out_s)
            is_resp = (recs["flags"] & serdes.FLAG_RESPONSE) != 0
            tel = tlm.observe(tel, recs["timestamp"], out_v & is_resp)
            tel = tlm.tick(tel)
            return fst, cache, sess, tel, served, out_s, out_v

        return tstep

    # ------------------------------------------------------------------
    def make_run_steps(self):
        """Scan-fused steady-state serving loop (the engine treatment).

        ``run_steps(fst, cache, sess, params, in_slots [K, N, W],
        in_valid [K, N], tel=None)`` executes K serve steps in ONE
        device dispatch: the (fabric, cache, sessions) triple is the
        ``lax.scan`` carry with donated buffers, the per-step
        wire-ingress tiles are the scanned xs, and the egress tiles come
        back stacked.  The host stages K tiles up front and syncs once —
        the §4.4 offload principle applied to model serving (vs. one
        dispatch + sync per decode step).

        With ``tel`` (``telemetry.create()``, donated) the latency
        histogram rides the carry (see
        ``make_serve_step_telemetry``) and the updated Telemetry is
        appended to the returns.
        """
        step = self.make_serve_step()
        tstep = self.make_serve_step_telemetry()

        def run_steps(fst, cache, sess, params, in_slots, in_valid):
            def body(carry, x):
                fst, cache, sess, served = carry
                s, v = x
                fst, cache, sess, n, out_s, out_v = step(
                    fst, cache, sess, params, s, v)
                return (fst, cache, sess, served + n), (out_s, out_v)

            carry = (fst, cache, sess, jnp.int32(0))
            (fst, cache, sess, served), (out_slots, out_valid) = \
                jax.lax.scan(body, carry, (in_slots, in_valid))
            return fst, cache, sess, served, out_slots, out_valid

        def run_steps_tel(fst, cache, sess, tel, params, in_slots,
                          in_valid):
            def body(carry, x):
                fst, cache, sess, tel, served = carry
                s, v = x
                fst, cache, sess, tel, n, out_s, out_v = tstep(
                    fst, cache, sess, tel, params, s, v)
                return (fst, cache, sess, tel, served + n), (out_s, out_v)

            carry = (fst, cache, sess, tel, jnp.int32(0))
            (fst, cache, sess, tel, served), (out_slots, out_valid) = \
                jax.lax.scan(body, carry, (in_slots, in_valid))
            return fst, cache, sess, served, out_slots, out_valid, tel

        fn = jax.jit(run_steps, donate_argnums=(0, 1, 2))
        fn_tel = jax.jit(run_steps_tel, donate_argnums=(0, 1, 2, 3))

        def wrapped(fst, cache, sess, params, in_slots, in_valid,
                    tel=None):
            from repro.core.engine import unalias
            fst, cache, sess, tel = unalias(
                (fst, cache, sess, tel),
                protected=(params, in_slots, in_valid))
            if tel is None:
                return fn(fst, cache, sess, params, in_slots, in_valid)
            return fn_tel(fst, cache, sess, tel, params, in_slots,
                          in_valid)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        wrapped._jitted_tel = fn_tel
        return wrapped

    # ------------------------------------------------------------------
    def init_states_batch(self, n_tenants: int):
        """Stacked (fabric, cache, sessions) triples — one virtual NIC
        slot + decode batch per tenant, leading tenant axis."""
        from repro.core.engine import stack_states
        return stack_states([self.init_states()
                             for _ in range(n_tenants)])

    def make_tenant_run_steps(self):
        """Tenant-batched serving loop: ``jax.vmap`` of the fused serve
        step over a leading tenant axis, scanned over K ingress tiles.

        ``run_steps(fst, cache, sess, params, in_slots [K, T, N, W],
        in_valid [K, T, N], tel=None)`` serves T independent tenants
        (each with its own fabric, KV cache and session table, sharing
        one set of model weights) for K steps in ONE device dispatch;
        ``served`` comes back per-tenant [T].  States come from
        ``init_states_batch``; ``tel`` is
        ``telemetry.create_batch(T)`` — per-tenant histograms, appended
        to the returns.
        """
        step = self.make_serve_step()
        vstep = jax.vmap(step, in_axes=(0, 0, 0, None, 0, 0))
        vtstep = jax.vmap(self.make_serve_step_telemetry(),
                          in_axes=(0, 0, 0, 0, None, 0, 0))

        def run_steps(fst, cache, sess, params, in_slots, in_valid):
            t = in_slots.shape[1]

            def body(carry, x):
                fst, cache, sess, served = carry
                s, v = x
                fst, cache, sess, n, out_s, out_v = vstep(
                    fst, cache, sess, params, s, v)
                return (fst, cache, sess, served + n), (out_s, out_v)

            carry = (fst, cache, sess, jnp.zeros((t,), jnp.int32))
            (fst, cache, sess, served), (out_slots, out_valid) = \
                jax.lax.scan(body, carry, (in_slots, in_valid))
            return fst, cache, sess, served, out_slots, out_valid

        def run_steps_tel(fst, cache, sess, tel, params, in_slots,
                          in_valid):
            t = in_slots.shape[1]

            def body(carry, x):
                fst, cache, sess, tel, served = carry
                s, v = x
                fst, cache, sess, tel, n, out_s, out_v = vtstep(
                    fst, cache, sess, tel, params, s, v)
                return (fst, cache, sess, tel, served + n), (out_s, out_v)

            carry = (fst, cache, sess, tel, jnp.zeros((t,), jnp.int32))
            (fst, cache, sess, tel, served), (out_slots, out_valid) = \
                jax.lax.scan(body, carry, (in_slots, in_valid))
            return fst, cache, sess, served, out_slots, out_valid, tel

        fn = jax.jit(run_steps, donate_argnums=(0, 1, 2))
        fn_tel = jax.jit(run_steps_tel, donate_argnums=(0, 1, 2, 3))

        def wrapped(fst, cache, sess, params, in_slots, in_valid,
                    tel=None):
            from repro.core.engine import unalias
            fst, cache, sess, tel = unalias(
                (fst, cache, sess, tel),
                protected=(params, in_slots, in_valid))
            if tel is None:
                return fn(fst, cache, sess, params, in_slots, in_valid)
            return fn_tel(fst, cache, sess, tel, params, in_slots,
                          in_valid)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        wrapped._jitted_tel = fn_tel
        return wrapped

    # ------------------------------------------------------------------
    def shard_tenant_states(self, fst, cache, sess, mesh,
                            axis: str = "tenant"):
        """Place stacked (fabric, cache, sessions) triples on the mesh:
        tenant axis sharded, placement legalized via
        ``parallel.sharding.legalize_specs`` (see ``engine.shard_states``).
        """
        from repro.core.engine import shard_states
        return (shard_states(fst, mesh, axis),
                shard_states(cache, mesh, axis),
                shard_states(sess, mesh, axis))

    def _sharded_runner(self, mesh, axis: str, local,
                        n_scalar_args: int, n_device_outs: int):
        """Shared shard_map/donation plumbing for the sharded serving
        entry points — ``make_sharded_tenant_run_steps`` and
        ``make_sharded_tenant_run_until_global`` differ ONLY in their
        per-device loop body, so the spec wiring, jit donation,
        ``unalias`` guard and divisibility check live here once.

        ``local(fst, cache, sess, params, in_slots, in_valid,
        *scalars)`` is the per-device body returning ``(fst, cache,
        sess, served, <n_device_outs per-device lane outputs>,
        out_slots, out_valid)``; ``n_scalar_args`` replicated int32
        scalars are appended to the public signature.  States donate,
        weights stay replicated, tiles are sharded on their tenant dim.
        """
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.debug import sanitize
        sanitize.note_unsanitized_sharded("ServingEngine (sharded)")

        def run(fst, cache, sess, params, in_slots, in_valid, *scalars):
            shard = lambda t: jax.tree.map(lambda _: P(axis), t)
            repl = jax.tree.map(lambda _: P(), params)
            tile = P(None, axis)
            return shard_map(
                local, mesh=mesh,
                in_specs=(shard(fst), shard(cache), shard(sess), repl,
                          tile, tile) + (P(),) * n_scalar_args,
                out_specs=(shard(fst), shard(cache), shard(sess),
                           P(axis)) + (P(axis),) * n_device_outs
                          + (tile, tile),
                check_rep=False)(fst, cache, sess, params, in_slots,
                                 in_valid, *scalars)

        fn = jax.jit(run, donate_argnums=(0, 1, 2))

        def wrapped(fst, cache, sess, params, in_slots, in_valid,
                    *scalars):
            from repro.core.engine import unalias
            t = in_slots.shape[1]
            if t % mesh.shape[axis]:
                raise ValueError(
                    f"n_tenants={t} must divide over the "
                    f"{mesh.shape[axis]}-device '{axis}' mesh axis")
            scalars = tuple(jnp.asarray(s, jnp.int32) for s in scalars)
            fst, cache, sess = unalias(
                (fst, cache, sess),
                protected=(params, in_slots, in_valid) + scalars)
            return fn(fst, cache, sess, params, in_slots, in_valid,
                      *scalars)

        # jaxprlint registry hook: the inner jitted callable, so the
        # IR linter can lower/trace the donating entry point directly
        wrapped._jitted = fn
        return wrapped

    def make_sharded_tenant_run_steps(self, mesh=None,
                                      axis: str = "tenant"):
        """Mesh-sharded serving loop: the tenant axis of
        ``make_tenant_run_steps`` sharded over ``mesh`` with
        ``shard_map``, so each device owns whole NIC slots — fabric, KV
        cache and session table shards — while the model weights stay
        replicated (in_spec ``P()``).  Ingress/egress tiles ride the
        same placement ([K, T, N, W] sharded on the tenant dim).  Same
        signature as ``make_tenant_run_steps``; ``n_tenants`` must
        divide over the mesh axis.
        """
        if mesh is None:
            from repro.core.transport import make_tenant_mesh
            mesh = make_tenant_mesh(axis=axis)
        step = self.make_serve_step()
        vstep = jax.vmap(step, in_axes=(0, 0, 0, None, 0, 0))

        def local(fst, cache, sess, params, in_slots, in_valid):
            tl = in_slots.shape[1]

            def body(carry, x):
                fst, cache, sess, served = carry
                s, v = x
                fst, cache, sess, n, out_s, out_v = vstep(
                    fst, cache, sess, params, s, v)
                return (fst, cache, sess, served + n), (out_s, out_v)

            carry = (fst, cache, sess, jnp.zeros((tl,), jnp.int32))
            (fst, cache, sess, served), (out_slots, out_valid) = \
                jax.lax.scan(body, carry, (in_slots, in_valid))
            return fst, cache, sess, served, out_slots, out_valid

        return self._sharded_runner(mesh, axis, local,
                                    n_scalar_args=0, n_device_outs=0)

    def make_sharded_tenant_run_until_global(self, mesh=None,
                                             axis: str = "tenant"):
        """Global-completion serving sweep on the mesh (the
        ``ShardedTenantEngine.run_until_global`` treatment ported to LM
        serving): every device keeps running serve steps — consuming its
        staged ingress tiles in order — until the FLEET-WIDE served
        total (``psum`` over per-device counters in the while
        predicate) reaches ``global_target``, or ``max_steps`` elapse.

        ``run(fst, cache, sess, params, in_slots [K, T, N, W], in_valid
        [K, T, N], global_target, max_steps)`` returns ``(fst, cache,
        sess, served [T], dev_steps [D], out_slots [K, T, ...],
        out_valid [K, T, ...])``.  ``max_steps`` is clipped to K (only K
        ingress tiles are staged); egress tiles of steps the loop never
        reached come back zeroed/invalid.  ``dev_steps`` entries agree
        across devices (the psum predicate ends every device's loop on
        the same step).  States donate; weights stay replicated.
        """
        if mesh is None:
            from repro.core.transport import make_tenant_mesh
            mesh = make_tenant_mesh(axis=axis)
        step = self.make_serve_step()
        vstep = jax.vmap(step, in_axes=(0, 0, 0, None, 0, 0))

        def local(fst, cache, sess, params, in_slots, in_valid,
                  global_target, max_steps):
            k, tl = in_slots.shape[0], in_slots.shape[1]
            max_steps = jnp.minimum(jnp.asarray(max_steps, jnp.int32),
                                    jnp.int32(k))
            o_s, o_v = jax.eval_shape(
                lambda *a: vstep(*a)[4:6], fst, cache, sess, params,
                in_slots[0], in_valid[0])
            outs = jnp.zeros((k,) + o_s.shape, o_s.dtype)
            outv = jnp.zeros((k,) + o_v.shape, o_v.dtype)

            def cond(c):
                served, steps = c[3], c[4]
                total = jax.lax.psum(jnp.sum(served), axis)
                return (total < global_target) & (steps < max_steps)

            def body(c):
                fst, cache, sess, served, steps, outs, outv = c
                s = jax.lax.dynamic_index_in_dim(in_slots, steps, 0,
                                                 keepdims=False)
                v = jax.lax.dynamic_index_in_dim(in_valid, steps, 0,
                                                 keepdims=False)
                fst, cache, sess, n, os_, ov_ = vstep(fst, cache, sess,
                                                      params, s, v)
                outs = jax.lax.dynamic_update_index_in_dim(outs, os_,
                                                           steps, 0)
                outv = jax.lax.dynamic_update_index_in_dim(outv, ov_,
                                                           steps, 0)
                return fst, cache, sess, served + n, steps + 1, outs, outv

            carry = (fst, cache, sess, jnp.zeros((tl,), jnp.int32),
                     jnp.int32(0), outs, outv)
            fst, cache, sess, served, steps, outs, outv = \
                jax.lax.while_loop(cond, body, carry)
            return fst, cache, sess, served, steps.reshape(1), outs, outv

        return self._sharded_runner(mesh, axis, local,
                                    n_scalar_args=2, n_device_outs=1)

    # ------------------------------------------------------------------
    def prefill_sessions(self, cache, sess: SessionState, prompts,
                         session_ids):
        """Batch-prefill ``prompts`` [Nslots, S] into fresh sessions."""
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, cache = self.model.prefill(self.params, batch, cache)
        s = prompts.shape[1]
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        sess = SessionState(jnp.asarray(session_ids, jnp.int32),
                            jnp.full((self.n_slots,), s, jnp.int32),
                            next_tok)
        return cache, sess, next_tok
