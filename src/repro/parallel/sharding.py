"""Sharding rules: DP / FSDP / TP / EP / SP over the production mesh.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  The ``pod`` axis is pure data parallelism (it joins ``data``
in every batch-dim spec), so one rule set covers both meshes.

Rules are name-based over the parameter pytree (paths end in the leaf
names created by the model zoo) and dimension-indexed FROM THE END so the
same rule covers stacked ([L, ...]) and unstacked layers:

* TP ("model"): attention head projections, FFN width, vocab, expert dim
  (EP), mamba inner channels, xLSTM gate blocks.
* FSDP ("data", only when ``cfg.fsdp``): the remaining large dim of each
  weight (ZeRO-3-style: params gathered on use).
* Optimizer state: always FSDP-sharded (ZeRO-1) even when params are
  replicated — ``opt_specs`` forces the fsdp rule on.
* KV caches: kv-head dim over "model" when divisible, else sequence (SP);
  MLA's headless compressed KV always shards sequence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return tuple(names)


def _mk(nd: int, dims=None) -> P:
    """Build a PartitionSpec assigning axes at (negative) dims."""
    spec = [None] * nd
    for d, axis in (dims or {}).items():
        if axis is not None:
            spec[nd + int(d) if d < 0 else int(d)] = axis
    return P(*spec)


# parameter leaves whose LAST dim is the TP (output-feature) dim
_TP_LAST = {"wq", "wk", "wv", "w_uq", "w_ukv", "w_in", "w_gate", "w_qkv",
            "w_gates", "r_gates", "bq", "bk", "bv", "lm_head", "conv",
            "w_dt"}
# parameter leaves whose dim -2 is the TP (input-feature) dim
_TP_MINUS2 = {"wo", "w_out", "w_x", "A_log"}
_REPLICATED = {"scale", "bias", "b_gates", "dt_bias", "b_if", "D",
               "router", "q_norm", "kv_norm", "proj"}


def _rule(names: Tuple[str, ...], shape, cfg: ModelConfig, dp, tp,
          fsdp: bool) -> P:
    name = names[-1]
    nd = len(shape)
    in_moe = "moe" in names
    if name == "tok":                       # embedding [V, d]
        return _mk(nd, {-2: tp, -1: dp if fsdp else None})
    if name == "frontend_proj":
        return _mk(nd, {-1: dp if fsdp else None})
    if name in ("D", "dt_bias", "b_gates", "b_if"):
        return _mk(nd)
    if name in _REPLICATED or (nd >= 1 and name == "scale"):
        if name == "router" and fsdp and nd >= 2:
            return _mk(nd, {-2: dp})      # [L, d, E]: d over data
        return _mk(nd)
    moe_ff = cfg.moe is not None and cfg.moe.fsdp_dim == "ff"
    if in_moe and name in ("w_in", "w_gate"):
        # [L, E, d, fe]: EP over model on E, fsdp on d (or fe)
        if moe_ff:
            return _mk(nd, {-3: tp, -1: dp if fsdp else None})
        return _mk(nd, {-3: tp, -2: dp if fsdp else None})
    if in_moe and name == "w_out":
        # [L, E, fe, d]: EP over model on E, fsdp on d (or fe)
        if moe_ff:
            return _mk(nd, {-3: tp, -2: dp if fsdp else None})
        return _mk(nd, {-3: tp, -1: dp if fsdp else None})
    if name in _TP_LAST:
        return _mk(nd, {-1: tp, -2: dp if (fsdp and nd >= 2) else None})
    if name in _TP_MINUS2:
        return _mk(nd, {-2: tp, -1: dp if fsdp else None})
    if name in ("w_dq", "w_dkv", "w_if"):   # small down-projections
        return _mk(nd, {-2: dp if fsdp else None})
    return _mk(nd)                          # default: replicate


def param_specs(cfg: ModelConfig, params_tree, dp="data", tp="model",
                fsdp=None):
    """Pytree of PartitionSpec matching ``params_tree`` (shapes or arrays)."""
    use_fsdp = cfg.fsdp if fsdp is None else fsdp

    def fn(path, leaf):
        shape = leaf.shape if hasattr(leaf, "shape") else np.shape(leaf)
        return _rule(_path_names(path), shape, cfg, dp, tp, use_fsdp)

    return jax.tree_util.tree_map_with_path(fn, params_tree)


def opt_specs(cfg: ModelConfig, params_tree, dp="data", tp="model"):
    """Optimizer-state specs: ZeRO — always fsdp-sharded."""
    return param_specs(cfg, params_tree, dp, tp, fsdp=True)


def batch_specs(batch_tree, dp=("data",)):
    """Batch dims over data(+pod) axes; everything else replicated."""
    dp_axes = dp if isinstance(dp, tuple) else (dp,)

    def fn(leaf):
        nd = len(leaf.shape if hasattr(leaf, "shape") else np.shape(leaf))
        return P(dp_axes, *([None] * (nd - 1))) if nd else P()

    return jax.tree.map(fn, batch_tree)


def cache_specs(cfg: ModelConfig, cache_tree, mesh_model: int,
                dp=("data",), tp="model"):
    """Decode-cache specs (see module docstring for the SP rules)."""
    dp_axes = dp if isinstance(dp, tuple) else (dp,)
    kv_tp_ok = cfg.n_kv_heads % mesh_model == 0 and cfg.attn_kind != "mla"

    def fn(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):      # [..., B, S, nkv, hd]
            spec = [None] * nd
            spec[nd - 4] = dp_axes
            if kv_tp_ok:
                spec[nd - 2] = tp
            else:
                spec[nd - 3] = tp               # SP over sequence
            return P(*spec)
        if name in ("ckv", "kpe"):              # [..., B, S, r]
            spec = [None] * nd
            spec[nd - 3] = dp_axes
            spec[nd - 2] = tp
            return P(*spec)
        if name == "conv":                      # [..., B, dc-1, di]
            return _mk_dp(nd, nd - 3, dp_axes, {nd - 1: tp})
        if name == "h":                         # [..., B, di, N]
            return _mk_dp(nd, nd - 3, dp_axes, {nd - 2: tp})
        # xlstm states (named leaves): batch-only sharding
        if name in ("sc", "sn", "sm", "sh", "mn"):   # [..., B, nh, hd]
            return _mk_dp(nd, nd - 3, dp_axes, {})
        if name == "mC":                        # [..., B, nh, hd, hd]
            return _mk_dp(nd, nd - 4, dp_axes, {})
        if name == "mm":                        # [..., B, nh]
            return _mk_dp(nd, nd - 2, dp_axes, {})
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(fn, cache_tree)


def decode_cache_specs(cfg: ModelConfig, cache_tree, mesh,
                       tenant_axis="tenant", tp_axis="model"):
    """Specs for TENANT-STACKED decode caches on a 2-D (tenant, model)
    serving mesh: leading tenant dim over ``tenant_axis``, kv-head dim
    over ``tp_axis`` — the layout the TP attention shards write into
    without any resharding.  ``cache_specs`` assumes the batch dim sits
    at nd-4 (training layout) so it cannot describe [T, Nslots, S, nkv,
    hd] leaves; this rule keys on the leaf names instead and is
    legalized against the actual shapes (non-divisible dims stay
    replicated, matching ``legalize_specs``' contract)."""
    def fn(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = len(leaf.shape)
        spec = [None] * nd
        if nd >= 1:
            spec[0] = tenant_axis
        if name in ("k", "v", "xk", "xv") and nd >= 2:
            spec[nd - 2] = tp_axis          # [..., S, nkv, hd]
        return P(*spec)

    specs = jax.tree_util.tree_map_with_path(fn, cache_tree)
    return legalize_specs(specs, cache_tree, mesh)


def _mk_dp(nd, b_dim, dp_axes, extra):
    spec = [None] * nd
    spec[b_dim] = dp_axes
    for d, a in extra.items():
        spec[d] = a
    return P(*spec)


def legalize_specs(spec_tree, array_tree, mesh):
    """Drop axis assignments whose dim size is not divisible by the mesh
    axis (pjit input shardings must divide evenly).  Multi-axis entries
    (e.g. ("pod","data")) use the product of their sizes."""
    sizes = dict(mesh.shape)

    def ax_size(entry):
        if entry is None:
            return 1
        if isinstance(entry, (tuple, list)):
            out = 1
            for a in entry:
                out *= sizes[a]
            return out
        return sizes[entry]

    def fn(spec, arr):
        shape = arr.shape if hasattr(arr, "shape") else np.shape(arr)
        out = []
        for d, entry in enumerate(spec):
            n = ax_size(entry)
            out.append(entry if (n > 1 and shape[d] % n == 0) or n == 1
                       else None)
        # spec may be shorter than ndim; P pads with None implicitly
        return P(*out)

    return jax.tree.map(fn, spec_tree, array_tree,
                        is_leaf=lambda x: isinstance(x, P))
