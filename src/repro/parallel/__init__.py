from repro.parallel.sharding import (batch_specs, cache_specs,  # noqa: F401
                                     legalize_specs, opt_specs, param_specs)
