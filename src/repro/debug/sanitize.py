"""FABRIC_SANITIZE — a checkify-backed runtime sanitizer for the fabric.

The static pass (``scripts/fabriclint``) machine-checks what the AST can
see; this module machine-checks what only execution can see.  With
``FABRIC_SANITIZE=1`` in the environment, the engines' fused entry
points are rebuilt through ``jax.experimental.checkify`` so that every
device window also proves, *inside* the scan/while bodies:

* **checkify error sets** — NaN/Inf production (``float_checks``)
  anywhere in the step, plus (under ``FABRIC_SANITIZE=strict``)
  out-of-bounds gathers/scatters; strict mode is opt-in because the
  dataplane's drop semantics intentionally scatter to a sentinel OOB
  index (see :data:`ERRORS`);
* **fabric invariants** (``user_checks`` via :func:`check_fabric`) —
  every ring's cursor pair satisfies ``0 <= tail - head <= entries`` and
  the free-slot FIFO satisfies ``0 <= tail - head <= capacity``, i.e. no
  consumer ran past its producer and nothing overfilled a ring.  These
  are the BRAM-pointer well-formedness conditions the paper's RTL gets
  from construction and our functional rings get only by discipline.

Cost model: sanitized entry points disable buffer donation (the checkify
error value must not alias a donated carry) and sync once per window to
raise pending errors — run it in CI and debugging sessions, never in
timed benchmarks.  The sharded engine is intentionally NOT sanitized:
checkify under ``shard_map`` with per-lane collectives is unsupported
territory, and the tenant engine already executes the identical step
code (the bit-exactness contract covers the sharded path).

Host-side (un-jitted) verifiers complement the device checks:
:func:`verify_telemetry` (histogram mass == completion count) and
:func:`verify_ledger` (the load-generator conservation law
``injected == completed + in_flight + fabric_drops``) raise
:class:`FabricInvariantError` on violation.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import checkify

#: default error set: fabric invariant checks + NaN/Inf.  ``index_checks``
#: is deliberately NOT default: the dataplane's drop semantics are built
#: on sentinel out-of-bounds scatters (``.at[...].set(mode="drop")`` with
#: index == capacity), which checkify flags even though ``mode="drop"``
#: defines them — so full index checking only makes sense on code paths
#: with no intentional sentinel drops (``FABRIC_SANITIZE=strict``).
ERRORS = checkify.user_checks | checkify.float_checks
STRICT_ERRORS = ERRORS | checkify.index_checks

#: client-side drop counters already accounted by the generator's own
#: ``dropped`` ledger are excluded; everything downstream counts
_DROP_KEYS_BOTH = ("drops_no_slot", "drops_fifo_full", "drops_rx_full",
                   "drops_exchange")
_DROP_KEYS_SERVER = ("drops_tx_full",)


class FabricInvariantError(AssertionError):
    """A host-side fabric conservation law failed."""


def enabled() -> bool:
    """True when the ``FABRIC_SANITIZE`` env var requests sanitizing."""
    return os.environ.get("FABRIC_SANITIZE", "").strip().lower() not in (
        "", "0", "false", "off")


def note_unsanitized_sharded(name: str) -> None:
    """Point at the static coverage when sanitizing can't apply.

    Called by the sharded factories (``ShardedTenantEngine``, the
    serving/decode shard_map runners) when ``FABRIC_SANITIZE`` is set:
    checkify cannot cross ``shard_map`` with per-lane collectives, and
    silently constructing an unsanitized engine would let the caller
    believe the whole run was checked.  The warning names the tier that
    DOES cover the sharded dataplane — the jaxprlint IR contracts.
    """
    if not enabled():
        return
    import warnings
    warnings.warn(
        f"FABRIC_SANITIZE is set but {name} runs UNSANITIZED: checkify "
        f"cannot cross shard_map with per-lane collectives. The sharded "
        f"dataplane is covered statically instead — run `python -m "
        f"scripts.jaxprlint` (FLJ101 collective schedules, FLJ102 "
        f"donation, FLJ103 counter bounds, FLJ104 scatter modes, FLJ105 "
        f"wire cost) — and sanitize the bit-identical TenantEngine path "
        f"at runtime.", RuntimeWarning, stacklevel=3)


def error_set():
    """The checkify error set for this process: ``FABRIC_SANITIZE=strict``
    adds ``index_checks`` (only usable on paths without sentinel-drop
    scatters — see :data:`ERRORS`); any other truthy value gets the
    default invariant + NaN set."""
    if os.environ.get("FABRIC_SANITIZE", "").strip().lower() == "strict":
        return STRICT_ERRORS
    return ERRORS


# ------------------------------------------------------------- device side
def check_ring(ring, name: str) -> None:
    """checkify the cursor-pair well-formedness of one ``Ring``.

    Occupancy ``tail - head`` must stay within ``[0, entries]`` for every
    queue (and every stacked tenant — the reduction is over all leading
    axes, so the same check covers [Q] and [T, Q] cursor layouts).
    """
    occ = ring.tail - ring.head
    cap = ring.buf.shape[-2]
    checkify.check(jnp.all(occ >= 0),
                   name + " ring: head ran past tail (occupancy < 0)")
    checkify.check(jnp.all(occ <= cap),
                   name + " ring: occupancy exceeds capacity "
                   "(producer overran consumer)")


def check_free(free, name: str) -> None:
    """checkify the free-slot FIFO: ``0 <= tail - head <= capacity``."""
    avail = free.tail - free.head
    cap = free.fifo.shape[-1]
    checkify.check(jnp.all(avail >= 0),
                   name + " free fifo: negative availability")
    checkify.check(jnp.all(avail <= cap),
                   name + " free fifo: more slots free than exist "
                   "(double release)")


def check_fabric(st, name: str) -> None:
    """checkify every ring/FIFO invariant of one ``FabricState``."""
    check_ring(st.tx, name + ".tx")
    check_ring(st.rx, name + ".rx")
    check_ring(st.flow_fifo, name + ".flow_fifo")
    check_free(st.free, name + ".free")


def wrap_step(step):
    """Wrap an engine step so each iteration re-proves the fabric
    invariants on its OUTPUT states.  Signature-preserving:
    ``(cst, sst, ht) -> (cst, sst, ht, done, dvalid)``.  The checks are
    ``checkify.check`` calls, so the wrapped step is only callable
    through a ``checkify.checkify``-transformed entry point
    (:func:`checked_jit`)."""

    @functools.wraps(step)
    def sanitized(cst, sst, ht):
        cst, sst, ht, done, dvalid = step(cst, sst, ht)
        check_fabric(cst, "client")
        check_fabric(sst, "server")
        return cst, sst, ht, done, dvalid

    return sanitized


def checked_jit(fn, static_argnums=()):
    """``jax.jit`` an entry point through checkify, raising eagerly.

    The returned callable runs the functionalized program, then calls
    ``checkify.check_error`` — one host sync per window, which surfaces
    the FIRST failed check (user/index/float) as a Python exception at
    the call site instead of silently corrupting downstream state.
    """
    cfn = jax.jit(checkify.checkify(fn, errors=error_set()),
                  static_argnums=static_argnums)

    @functools.wraps(fn)
    def call(*args):
        err, out = cfn(*args)
        checkify.check_error(err)
        return out

    return call


# --------------------------------------------------------------- host side
def verify_telemetry(tel) -> None:
    """Histogram conservation: every completion observed is binned
    exactly once, so ``hist.sum() == n_done``."""
    hist_mass = int(np.asarray(jax.device_get(tel.hist)).sum())
    n_done = int(np.asarray(jax.device_get(tel.n_done)).sum())
    if hist_mass != n_done:
        raise FabricInvariantError(
            f"telemetry conservation violated: histogram mass "
            f"{hist_mass} != n_done {n_done} (a completion was binned "
            f"twice or not at all)")


def _mon_sum(mon, key) -> int:
    return int(np.asarray(jax.device_get(mon[key])).sum())


def fabric_drops(cst, sst) -> int:
    """Drop counters downstream of the generator's own ledger (the
    client's ``drops_tx_full`` rejections are already its ``dropped``)."""
    tot = 0
    for key in _DROP_KEYS_BOTH:
        tot += _mon_sum(cst.mon, key) + _mon_sum(sst.mon, key)
    for key in _DROP_KEYS_SERVER:
        tot += _mon_sum(sst.mon, key)
    return tot


def verify_ledger(gst, cst, sst, completed) -> None:
    """Load-generator conservation law over a window:

    ``offered == injected + dropped`` (generator-internal, by
    construction) and ``injected == completed + in_flight +
    fabric_drops`` — every arrival the generator accepted is either
    done, still resident in a ring/FIFO, or counted by a monitor drop.
    """
    from repro.core import loadgen

    snap = loadgen.snapshot(gst)
    if snap["offered"] != snap["injected"] + snap["dropped"]:
        raise FabricInvariantError(
            f"loadgen ledger violated: offered {snap['offered']} != "
            f"injected {snap['injected']} + dropped {snap['dropped']}")
    in_flight = loadgen.system_occupancy(cst, sst)
    done = int(np.asarray(jax.device_get(completed)).sum())
    drops = fabric_drops(cst, sst)
    if snap["injected"] != done + in_flight + drops:
        raise FabricInvariantError(
            f"fabric conservation violated: injected {snap['injected']} "
            f"!= completed {done} + in_flight {in_flight} + "
            f"fabric_drops {drops} (an RPC was lost or double-counted)")
