"""Runtime debugging aids for the fabric (see ``repro.debug.sanitize``)."""
