"""Central configuration for Dagger-JAX.

Two config families:

* ``ModelConfig`` — describes any of the 10 assigned architectures (plus
  reduced smoke-test variants).  One frozen dataclass drives model building,
  sharding rules, dry-run input specs, and the serving engine.

* ``FabricConfig`` — the Dagger NIC analogue.  Fields are split between
  *hard* configuration (changing them produces a new jit trace — the paper's
  re-synthesis) and *soft* configuration (runtime device scalars — the
  paper's CSR writes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

# Layer kinds used by hybrid stacks (jamba / xlstm / gemma patterns).
ATTN_GLOBAL = 0
ATTN_LOCAL = 1
MAMBA = 2
SLSTM = 3
MLSTM = 4


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 0
    n_shared: int = 0               # shared (always-on) experts
    d_ff_expert: int = 0            # per-expert FFN width
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    # which layers are MoE: "all", "every_other", or "after:N" (dense first N)
    layer_pattern: str = "all"
    # decode-path dispatch: "dense" (all experts x capacity, EP-friendly)
    # or "gather" (per-assignment expert-weight gather — flop/byte-optimal
    # for tiny decode batches; §Perf hillclimb knob)
    decode_mode: str = "dense"
    # FSDP dim for expert weights: "d" shards d_model (contraction dim of
    # the dispatch einsum -> per-einsum partial-sum all-reduces) or "ff"
    # shards d_ff_expert (keeps h sharded through the GLU, one reduce at
    # the output projection).  §Perf hillclimb knob.
    fsdp_dim: str = "d"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16               # mamba state dim
    d_conv: int = 4
    expand: int = 2
    # xlstm
    xlstm_heads: int = 4
    # selective-scan tiling (§Perf hillclimb knobs): chunk length of the
    # outer scan, and the dtype of the materialized [B,chunk,di,N] state
    chunk: int = 256
    scan_dtype: str = "float32"


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    head_dim: int = 0               # 0 -> d_model // n_heads
    d_ff: int = 4096
    vocab: int = 32000
    max_seq: int = 131072

    # attention details
    attn_kind: str = "gqa"          # gqa | mla
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    local_window: int = 0           # >0 enables sliding-window layers
    local_pattern: int = 0          # N local layers per 1 global (gemma 5:1)
    logit_softcap: float = 0.0

    # FFN
    mlp_act: str = "swiglu"         # swiglu | gelu | sqrelu | relu
    norm_kind: str = "rmsnorm"      # rmsnorm | layernorm
    tie_embeddings: bool = False

    # mixtures / recurrence
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid interleave: length == period; e.g. jamba (ATTN,MAMBA*7)
    hybrid_pattern: Tuple[int, ...] = ()

    # encoder-decoder
    enc_layers: int = 0             # >0 -> enc-dec; n_layers is decoder depth

    # multimodal frontend stub: "" | "audio" | "vision"
    frontend: str = ""
    frontend_tokens: int = 0        # frames / patches per example
    frontend_dim: int = 0           # embedding dim produced by the stub

    # multi-token prediction (deepseek MTP) — extra heads
    mtp_depth: int = 0

    # numerics / memory
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # checkpointing policy for the layer scan: "dots" (save dot outputs),
    # "nothing" (full recompute), "everything" (no remat)
    remat_policy: str = "dots"
    fsdp: bool = False              # shard params over the data axis too
    use_pallas: bool = False        # route hot paths through Pallas kernels
    # Tensor-parallel mesh axis name for SPMD decode (shard_map): when
    # non-empty, the dense GQA + MLP decode path psums partial outputs
    # over this axis and the embedding/unembedding run vocab-parallel.
    # Only the dense-GQA decode path honors it; param shards must follow
    # ``parallel.sharding.param_specs(..., tp=tp_axis)``.
    tp_axis: str = ""
    # §Perf: compute attention scores via preferred_element_type instead of
    # materializing f32 casts of Q/K/V (saves HBM traffic on decode reads)
    fast_attn: bool = False
    # §Perf: KV-block size for flash (online-softmax) full attention;
    # 0 = dense scores (materializes [B,H,S,S] — the baseline)
    flash_block: int = 0
    # §Perf: constrain the residual stream's sequence dim onto the
    # "model" axis between blocks (sequence parallelism for norms /
    # elementwise; GSPMD inserts the gathers attention needs)
    seq_parallel: bool = False
    # §Perf: re-pin the residual stream's BATCH dim to these mesh axes
    # between blocks (comma-separated, e.g. "data" or "pod,data").
    # Without this, FSDP-sharded weights can make GSPMD replicate the
    # batch at inference (observed: 14x per-device work on phi3 prefill).
    batch_constraint: str = ""

    # decode behaviour
    supports_long_context: bool = False   # run the long_500k cell?

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter counting (for roofline MODEL_FLOPS = 6 N D) ------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts MoE top-k only."""
        d, f, V = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        nq, nkv = self.n_heads, self.n_kv_heads

        def attn_params() -> int:
            if self.attn_kind == "mla":
                m = self.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                p = d * m.q_lora_rank + m.q_lora_rank * nq * qk
                p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                p += m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                p += nq * m.v_head_dim * d
                return p
            return d * hd * (nq + 2 * nkv) + nq * hd * d

        def dense_ffn() -> int:
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            return mult * d * f

        def moe_ffn(active: bool) -> int:
            mo = self.moe
            n = (mo.top_k if active else mo.n_experts) + mo.n_shared
            mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
            return n * mult * d * mo.d_ff_expert + d * mo.n_experts

        def mamba_params() -> int:
            s = self.ssm
            di = s.expand * d
            return 2 * d * di + di * (2 * s.d_state + 2) + di * s.d_conv + di * d

        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d
        layers = self._layer_kinds()
        for kind, is_moe in layers:
            if kind in (ATTN_GLOBAL, ATTN_LOCAL):
                total += attn_params()
                total += moe_ffn(active_only) if is_moe else dense_ffn()
            elif kind == MAMBA:
                total += mamba_params()
                total += moe_ffn(active_only) if is_moe else dense_ffn()
            elif kind in (SLSTM, MLSTM):
                total += 4 * d * d + dense_ffn() // 2
        if self.enc_layers:
            # encoder self-attn + ffn + decoder cross-attn already excluded
            total += self.enc_layers * (attn_params() + dense_ffn())
            total += self.n_layers * attn_params()  # cross-attention
        return int(total)

    def _layer_kinds(self):
        """Return [(layer_kind, is_moe)] for the decoder stack."""
        out = []
        for i in range(self.n_layers):
            if self.hybrid_pattern:
                kind = self.hybrid_pattern[i % len(self.hybrid_pattern)]
            elif self.family == "ssm":
                kind = (SLSTM, MLSTM)[i % 2]
            elif self.local_pattern:
                kind = ATTN_GLOBAL if (i % (self.local_pattern + 1)
                                       == self.local_pattern) else ATTN_LOCAL
            else:
                kind = ATTN_GLOBAL
            is_moe = False
            if self.moe is not None:
                pat = self.moe.layer_pattern
                if pat == "all":
                    is_moe = True
                elif pat == "every_other":
                    is_moe = i % 2 == 1
                elif pat.startswith("after:"):
                    is_moe = i >= int(pat.split(":")[1])
            out.append((kind, is_moe))
        return out


# ---------------------------------------------------------------------------
# Fabric (Dagger NIC) configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FabricConfig:
    """Dagger NIC configuration.

    Hard configuration (paper: SystemVerilog macros, needs re-synthesis —
    here: retrace/recompile):
    """
    n_flows: int = 4                # NIC flows == RX/TX ring pairs (paper: <=512)
    ring_entries: int = 64          # slots per RX/TX ring
    slot_bytes: int = 64            # RPC MTU per slot (cache line analogue)
    conn_cache_entries: int = 256   # direct-mapped connection cache size
    interface: str = "upi"          # doorbell | doorbell_batch | mmio | upi
    lb_scheme: str = "round_robin"  # round_robin | static | object_level
    request_buffer_slots: int = 0   # 0 -> B * n_flows (paper §4.4.2)
    threading: str = "dispatch"     # dispatch | worker  (paper Table 4)
    use_pallas: bool = False

    # Soft configuration defaults (paper: CSR writes — here: device scalars):
    batch_size: int = 4             # CCI-P batching width B (paper: B=4 best)
    dynamic_batching: bool = True   # adapt B under load (paper Fig. 11 green)
    active_flows: int = 0           # 0 -> all flows active

    @property
    def resolved_request_buffer_slots(self) -> int:
        return self.request_buffer_slots or self.batch_size * self.n_flows

    def replace(self, **kw) -> "FabricConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Run / launcher configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the assignment matrix."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    microbatches: int = 1           # gradient accumulation
    grad_compression: str = "none"  # none | int8_ef  (cross-pod trick)
    opt_dtype: str = "float32"      # AdamW m/v dtype (bf16 for huge models)
    seed: int = 0


# ---------------------------------------------------------------------------
# Accelerator profiles — environment setup so the same bench commands run
# unmodified on CPU / GPU / TPU
# ---------------------------------------------------------------------------

# Each profile: env vars set BEFORE jax import (setdefault — an explicit
# user environment always wins) plus XLA flags APPENDED to XLA_FLAGS.
# The accelerator profiles enable the latency-hiding scheduler and async
# collectives so the switch step's exchange collectives overlap with the
# per-tier compute (the knobs the fused-switch benchmarks assume on real
# hardware); the cpu profile pins the host platform so container GPUs
# never surprise a reproduction run.
ACCEL_PROFILES = {
    "cpu": {
        "env": {"JAX_PLATFORMS": "cpu", "JAX_ENABLE_X64": "0"},
        "xla_flags": [],
    },
    "gpu": {
        "env": {"JAX_ENABLE_X64": "0"},
        "xla_flags": [
            "--xla_gpu_enable_latency_hiding_scheduler=true",
            "--xla_gpu_enable_highest_priority_async_stream=true",
        ],
    },
    "tpu": {
        "env": {"JAX_ENABLE_X64": "0"},
        "xla_flags": [
            "--xla_tpu_enable_latency_hiding_scheduler=true",
            "--xla_enable_async_all_gather=true",
            "--xla_enable_async_collective_permute=true",
        ],
    },
}


def apply_accel_profile(name: str) -> dict:
    """Apply an ``ACCEL_PROFILES`` entry to ``os.environ``.

    Must run before the first ``import jax`` to take effect (the bench
    runner's ``--accel-profile`` flag does this; jax is imported lazily
    inside the suite loop).  Env vars are ``setdefault`` so explicit user
    settings win; XLA flags are appended to any existing ``XLA_FLAGS``.
    Returns the applied profile.  Raises ``ValueError`` on unknown names.
    """
    import os
    try:
        prof = ACCEL_PROFILES[name]
    except KeyError:
        raise ValueError(
            f"unknown accel profile {name!r}; "
            f"pick one of {sorted(ACCEL_PROFILES)}") from None
    for k, v in prof["env"].items():
        os.environ.setdefault(k, v)
    if prof["xla_flags"]:
        existing = os.environ.get("XLA_FLAGS", "")
        add = " ".join(fl for fl in prof["xla_flags"] if fl not in existing)
        if add:
            os.environ["XLA_FLAGS"] = (existing + " " + add).strip()
    return prof


# Roofline hardware model (TPU v5e target, per assignment).
@dataclass(frozen=True)
class HWSpec:
    name: str = "tpu_v5e"
    peak_flops_bf16: float = 197e12      # per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw_per_link: float = 50e9        # bytes/s per link
    hbm_bytes: float = 16e9              # capacity per chip
    vmem_bytes: float = 128 * 2 ** 20


HW = HWSpec()
